"""Hybrid kernel dispatch (kernels.dispatch) + worker-pool timing fixes.

Covers the PR-3 regressions — duplicate per-worker sub-tasks must
accumulate (not last-write-win), background-load intervals must integrate
over the task's own time span — and the dispatch layer's contracts: shard
outputs identical to the monolithic kernels, ratio convergence and
achieved-bandwidth fractions on the simulated hybrid machines, and the
balanced model-layer wrappers.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoreSpec, SimulatedHybridCPU, make_machine
from repro.core.pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from repro.kernels import (
    GEMV_ISA,
    HybridKernelDispatcher,
    int8_linear,
    ops,
    ref,
)
from repro.models.layers import BalancedLinear, BalancedQuantLinear
from repro.quant import (
    quantize_q4_0,
    quantize_s8_symmetric,
    quantize_u8_dynamic,
)
from repro.runtime import KernelSpec

RNG = np.random.default_rng(0)


def one_core_machine(tp: float = 1.0, background=()):
    """Deterministic single-core machine: jitter 0, throughput ``tp``."""
    m = SimulatedHybridCPU(
        cores=[CoreSpec("C0", "P", {"avx2": tp}, jitter=0.0)])
    m.background.extend(background)
    return m


# ------------------------------------------------- pool: multi-subtask ----
def test_thread_pool_runs_all_subtasks_per_worker():
    """Regression: two sub-tasks for the same worker used to last-write-win
    (the first one's work silently dropped)."""
    out = np.zeros(8)
    fn = lambda start, size: out.__setitem__(slice(start, start + size), 1)
    pool = ThreadWorkerPool(2)
    try:
        times = pool.run([
            SubTask(worker=0, start=0, size=2, work=2, fn=fn),
            SubTask(worker=0, start=2, size=2, work=2, fn=fn),
            SubTask(worker=1, start=4, size=4, work=4, fn=fn),
        ])
    finally:
        pool.close()
    np.testing.assert_array_equal(out, 1.0)
    assert times[0] > 0 and times[1] > 0


def test_thread_pool_propagates_shard_errors_without_deadlock():
    """A raising shard fn must surface in run() (not kill the worker thread
    and hang the join), and the pool must stay usable afterwards."""
    def bad(start, size):
        raise RuntimeError("boom")

    pool = ThreadWorkerPool(2)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            pool.run([SubTask(worker=0, start=0, size=1, work=1, fn=bad)])
        times = pool.run([SubTask(worker=0, start=0, size=1, work=1,
                                  fn=lambda s, z: None)])
        assert times[0] >= 0
    finally:
        pool.close()


def test_virtual_pool_accumulates_duplicate_worker_times():
    """Regression: ``times[st.worker] =`` dropped all but the last
    sub-task's time; chunked shard dispatch needs the sum."""
    pool = VirtualWorkerPool(one_core_machine(tp=1.0), isa="avx2")
    times = pool.run([
        SubTask(worker=0, start=0, size=1, work=3.0),
        SubTask(worker=0, start=1, size=1, work=4.0),
    ])
    np.testing.assert_allclose(times[0], 7.0)
    assert pool.clock == pytest.approx(7.0)


# ------------------------------------- background-interval integration ----
def test_background_starting_mid_task_is_applied():
    """A throttle interval that begins mid-task used to be missed entirely
    (slowdown sampled once at region start)."""
    m = one_core_machine(tp=1.0, background=[(5.0, 1e9, 0, 2.0)])
    # 10 base-seconds from t=0: 5s unthrottled, remaining 5 at 2x -> 15s.
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(15.0)


def test_background_ending_mid_task_not_over_applied():
    """An interval that ends mid-task used to throttle the whole task."""
    m = one_core_machine(tp=1.0, background=[(0.0, 2.0, 0, 3.0)])
    # 2 wall-seconds at 3x consume 2/3 base; the rest runs unthrottled.
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(
        2.0 + (10.0 - 2.0 / 3.0))


def test_constant_background_matches_point_sample():
    """An interval covering the whole task reduces to the old behaviour."""
    m = one_core_machine(tp=1.0, background=[(0.0, 1e9, 0, 3.0)])
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(30.0)


def test_virtual_pool_sequential_subtasks_hit_their_own_interval():
    """The second sub-task of a worker starts at the virtual instant the
    first finished — a throttle starting between them lands on it."""
    m = one_core_machine(tp=1.0, background=[(5.0, 1e9, 0, 2.0)])
    pool = VirtualWorkerPool(m, isa="avx2")
    times = pool.run([
        SubTask(worker=0, start=0, size=1, work=5.0),   # t in [0, 5): clean
        SubTask(worker=0, start=1, size=1, work=5.0),   # starts at 5: 2x
    ])
    np.testing.assert_allclose(times[0], 5.0 + 10.0)


# --------------------------------------------- dispatch: shard outputs ----
def test_q4_shards_byte_identical_to_monolithic():
    x = jnp.asarray(RNG.normal(size=(4, 512)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(300, 512)).astype(np.float32)))
    disp = HybridKernelDispatcher.virtual("core-12900k", execute=True)
    got = disp.q4_matmul(x, qw, blocks=(8, 256, 512))
    want = ops.q4_matmul(x, qw, blocks=(8, 256, 512), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_shards_identical_via_thread_pool():
    a = jnp.asarray(RNG.integers(0, 256, size=(16, 256)), dtype=jnp.uint8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(200, 256)), dtype=jnp.int8)
    disp = HybridKernelDispatcher.threaded(4)
    try:
        for _ in range(2):  # tuner explores different shard blocks; s32 exact
            got = disp.int8_gemm(a, w)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref.int8_gemm_ref(a, w)))
    finally:
        disp.close()


def test_virtual_dispatcher_without_execute_refuses_kernels():
    disp = HybridKernelDispatcher.virtual("ultra-125h")  # execute=False
    x = jnp.zeros((1, 64), jnp.float32)
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(32, 64)).astype(np.float32)))
    with pytest.raises(ValueError, match="execute"):
        disp.q4_matmul(x, qw)


# ------------------------------------- dispatch: the paper's claims -------
GEMV_SPEC = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                       work_per_unit=4096 * 0.5625)


@pytest.mark.parametrize("machine", ["ultra-125h", "core-12900k"])
def test_dynamic_dispatch_reaches_bandwidth_fraction(machine):
    """Paper Fig. 2: dynamic shard dispatch sustains >90% of the socket's
    streaming bandwidth; static (equal shards) stays materially lower."""
    def frac(dynamic, iters):
        disp = HybridKernelDispatcher.virtual(machine, dynamic=dynamic)
        for _ in range(iters):
            disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
        tail = disp.stats[-10:]
        moved = sum(st.bytes for st in tail)
        busy = sum(st.makespan for st in tail)
        return (moved / busy) / disp.machine.socket_bandwidth

    dyn, sta = frac(True, 40), frac(False, 10)
    assert dyn > 0.90, f"{machine}: dynamic achieved {dyn:.2%}"
    assert dyn > sta + 0.05, f"{machine}: dynamic {dyn:.2%} vs static {sta:.2%}"


def test_dispatch_ratios_converge_to_true_throughput():
    machine = make_machine("ultra-125h")
    disp = HybridKernelDispatcher.virtual(machine)
    for _ in range(40):
        disp.dispatch(GEMV_SPEC, 4096)
    ratios = disp.table.ratios(GEMV_ISA)
    tp = machine.true_throughput(GEMV_ISA)
    np.testing.assert_allclose(ratios, tp / tp.mean(), rtol=0.10)


def test_bytes_telemetry_on_region_stats():
    disp = HybridKernelDispatcher.virtual("ultra-125h")
    st = disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
    assert st.bytes == pytest.approx(4096 * 4096 * 0.5625)
    assert st.bandwidth > 0
    assert disp.achieved_bandwidth() == pytest.approx(st.bandwidth)


# --------------------------------------------------- balanced layers ------
def test_balanced_quant_linear_matches_reference():
    w = RNG.normal(size=(96, 64)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    layer = BalancedQuantLinear.from_dense(jnp.asarray(w), disp)
    got = layer(x, isa=GEMV_ISA)
    want = ref.q4_matmul_ref(x, quantize_q4_0(jnp.asarray(w)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-2)
    # 3D hidden states (B, S, d) round-trip through the same dispatch
    x3 = x.reshape(2, 2, 64)
    got3 = layer(x3)
    np.testing.assert_allclose(np.asarray(got3).reshape(4, -1),
                               np.asarray(got), rtol=1e-6, atol=1e-6)


def test_balanced_linear_matches_int8_linear():
    w = RNG.normal(size=(48, 64)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(5, 64)).astype(np.float32))
    disp = HybridKernelDispatcher.virtual("core-12900k", execute=True)
    layer = BalancedLinear.from_dense(jnp.asarray(w), disp)
    got = layer(x)
    qa = quantize_u8_dynamic(x)
    qw = quantize_s8_symmetric(jnp.asarray(w))
    want = int8_linear(qa, qw, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------- engine hot-path wiring -------
def test_engine_decodes_through_balanced_head():
    """ContinuousBatchingEngine + balanced Q4 LM head: requests finish,
    both per-phase ISA keys are learned from real shard dispatches, and
    bandwidth accounting accumulates."""
    from repro.configs import reduced_config
    from repro.models import balanced_lm_head, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
        cost_model=HybridPhaseCost("ultra-125h"),
        balanced_head=balanced_lm_head(cfg, params, disp))
    requests = poisson_requests(3, rate=100.0, vocab_size=cfg.vocab_size,
                                prompt_len=6, max_new_tokens=4, seed=0)
    for r in requests:
        engine.submit(r)
    engine.run_until_idle()
    assert all(len(r.generated) == 4 for r in requests)
    assert sorted(disp.table.keys()) == ["avx_vnni", "membw"]
    # decode GEMVs moved bytes through the membw-keyed regions
    assert disp.achieved_bandwidth(GEMV_ISA) > 0
    spread = disp.table.ratios(GEMV_ISA)
    assert spread.max() / spread.min() > 1.1  # hybrid cores differentiated
