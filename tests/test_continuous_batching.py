"""Continuous-batching engine: admission, slot reuse, chunked prefill,
per-phase ratio learning, latency metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import forward, init_params
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    FinishReason,
    HybridPhaseCost,
    LatencyReport,
    LinearPhaseCost,
    Request,
    RequestState,
    poisson_requests,
)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
PARAMS = init_params(CFG, jax.random.key(0))

CFG_HYBRID = ModelConfig(name="h", family="hybrid", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                         dtype="float32", mixer_pattern=("attn", "mamba"),
                         ssm=SSMConfig())
PARAMS_HYBRID = init_params(CFG_HYBRID, jax.random.key(1))


def _engine(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 32)
    kw.setdefault("cost_model", LinearPhaseCost())
    return ContinuousBatchingEngine(CFG, PARAMS, **kw)


def _requests(n, prompt_len=6, steps=4, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, CFG.vocab_size, size=prompt_len),
                    max_new_tokens=steps, **kw) for _ in range(n)]


# ---------------------------------------------------------- correctness ---
def test_engine_greedy_matches_full_forward():
    eng = _engine(max_slots=3)
    reqs = _requests(4, steps=3)
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle(max_steps=100)
    for r in reqs:
        assert r.state is RequestState.FINISHED
        toks = r.tokens
        for k in range(r.prompt_len, len(toks)):
            full = forward(CFG, PARAMS, jnp.asarray(toks[None, :k]))
            expect = int(np.asarray(jnp.argmax(full.logits[0, -1], -1)))
            assert toks[k] == expect


def test_engine_hybrid_arch_with_slot_reuse():
    """SSM states must survive adoption/eviction scatter, and a reused slot
    must not leak its previous occupant's cache."""
    eng = ContinuousBatchingEngine(CFG_HYBRID, PARAMS_HYBRID, max_slots=2,
                                   max_seq=24, cost_model=LinearPhaseCost())
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(0, 64, size=5), max_new_tokens=4)
            for _ in range(4)]  # 4 requests through 2 slots -> reuse
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle(max_steps=200)
    assert eng.manager.n_free == 2
    for r in reqs:
        toks = r.tokens
        full = forward(CFG_HYBRID, PARAMS_HYBRID, jnp.asarray(toks[None, :-1]))
        expect = int(np.asarray(jnp.argmax(full.logits[0, -1], -1)))
        assert toks[-1] == expect


def test_chunked_prefill_equivalent_to_one_shot():
    prompt = np.arange(10, dtype=np.int32) % CFG.vocab_size
    outs = []
    for chunk in (None, 3):
        eng = _engine(prefill_chunk=chunk)
        req = Request(prompt=prompt, max_new_tokens=5)
        eng.submit(req)
        eng.run_until_idle(max_steps=100)
        outs.append(req.tokens)
    np.testing.assert_array_equal(outs[0], outs[1])


def test_prefill_lanes_identical_tokens():
    """Batching queued prefills into one trunk call per iteration (ISSUE 6
    satellite) must be a pure throughput change: per-request tokens are
    bit-identical to the single-lane engine, and the lane engine spends
    fewer iterations doing it."""
    steps_by_lanes = {}
    tokens_by_lanes = {}
    for lanes in (1, 2, 3):
        eng = _engine(max_slots=4, prefill_chunk=8, prefill_lanes=lanes)
        # mixed prompt lengths -> mixed power-of-two buckets per lane; the
        # shared chunk length is the min bucket (itself a power of two)
        reqs = [Request(prompt=np.arange(n, dtype=np.int32) % CFG.vocab_size,
                        max_new_tokens=4) for n in (5, 11, 7, 13)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_idle(max_steps=200)
        steps_by_lanes[lanes] = len(stats)
        tokens_by_lanes[lanes] = [r.tokens for r in reqs]
        assert all(r.state is RequestState.FINISHED for r in reqs)
    for lanes in (2, 3):
        for a, b in zip(tokens_by_lanes[1], tokens_by_lanes[lanes]):
            np.testing.assert_array_equal(a, b)
        assert steps_by_lanes[lanes] < steps_by_lanes[1]


def test_prefill_lanes_hybrid_state_stacking():
    """Multi-lane prefill must stack and re-slice *mixed* recurrent state
    (KV caches + SSM states) without corruption: every request's next
    token still matches the full forward pass."""
    eng = ContinuousBatchingEngine(CFG_HYBRID, PARAMS_HYBRID, max_slots=3,
                                   max_seq=24, prefill_chunk=4,
                                   prefill_lanes=3,
                                   cost_model=LinearPhaseCost())
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, 64, size=n), max_new_tokens=3)
            for n in (4, 7, 5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle(max_steps=200)
    for r in reqs:
        toks = r.tokens
        full = forward(CFG_HYBRID, PARAMS_HYBRID, jnp.asarray(toks[None, :-1]))
        expect = int(np.asarray(jnp.argmax(full.logits[0, -1], -1)))
        assert toks[-1] == expect


def test_prefill_lanes_abort_mid_prefill():
    """Aborting one lane mid-prefill frees its slot and partial state while
    the surviving lanes finish normally."""
    eng = _engine(max_slots=2, prefill_chunk=2, prefill_lanes=2)
    a = Request(prompt=np.arange(12, dtype=np.int32) % CFG.vocab_size,
                max_new_tokens=3)
    b = Request(prompt=np.arange(10, dtype=np.int32) % CFG.vocab_size,
                max_new_tokens=3)
    eng.submit(a)
    eng.submit(b)
    eng.step()
    assert a.state is RequestState.PREFILL
    assert b.state is RequestState.PREFILL
    assert eng.n_prefilling == 2
    assert eng.abort(a) and a.finish_reason is FinishReason.ABORTED
    assert eng.n_prefilling == 1
    eng.run_until_idle(max_steps=100)
    assert b.state is RequestState.FINISHED
    assert eng.manager.n_free == 2
    # the aborted request's tokens match a fresh single-lane run of b
    ref = Request(prompt=np.arange(10, dtype=np.int32) % CFG.vocab_size,
                  max_new_tokens=3)
    ref_eng = _engine(max_slots=1, prefill_chunk=2)
    ref_eng.submit(ref)
    ref_eng.run_until_idle(max_steps=100)
    np.testing.assert_array_equal(b.tokens, ref.tokens)


def test_scheduler_lane_admission_respects_slots():
    """Lanes never outrun free slots: each admission reserves one."""
    from repro.serving import IterationScheduler

    sched = IterationScheduler(prefill_chunk=8, prefill_lanes=3)
    for k in range(4):
        sched.submit(Request(prompt=np.arange(6 + k), max_new_tokens=2))
    chunks = sched.next_prefill(now=0.0, free_slots=2)
    assert len(chunks) == 2          # slot-limited, not lane-limited
    assert len({c.length for c in chunks}) == 1  # shared chunk length
    chunks = sched.next_prefill(now=0.0, free_slots=1)
    assert len(chunks) == 3          # third lane opens with the freed slot


# ------------------------------------------------------------ scheduling ---
def test_admission_in_arrival_order():
    eng = _engine(max_slots=1)
    late = _requests(1, seed=1, arrival_time=0.5)[0]
    early = _requests(1, seed=2, arrival_time=0.0)[0]
    eng.submit(late)   # submitted first, arrives later
    eng.submit(early)
    eng.run_until_idle(max_steps=200)
    assert early.admit_time < late.admit_time
    assert early.first_token_time < late.first_token_time


def test_late_request_joins_inflight_batch():
    """No barrier: a request arriving mid-decode of another is admitted
    before the first finishes."""
    eng = _engine(max_slots=2, cost_model=LinearPhaseCost(
        prefill_per_token=1e-3, decode_per_step=1e-2))
    long_req = _requests(1, steps=20)[0]
    late = _requests(1, seed=3, steps=2, arrival_time=0.05)[0]
    eng.submit(long_req)
    eng.submit(late)
    eng.run_until_idle(max_steps=300)
    assert late.admit_time > long_req.first_token_time   # joined mid-flight
    assert late.finish_time < long_req.finish_time       # and left first


def test_chunked_prefill_lengths_are_power_of_two_buckets():
    """Varying prompt lengths must not grow the jitted prefill shape set:
    chunk lengths are power-of-two buckets <= prefill_chunk."""
    eng = _engine(max_slots=1, prefill_chunk=8)
    req = Request(prompt=np.arange(13, dtype=np.int32) % CFG.vocab_size,
                  max_new_tokens=2)
    eng.submit(req)
    stats = eng.run_until_idle(max_steps=100)
    lengths = [s.prefill_tokens for s in stats if s.prefill_tokens]
    assert sum(lengths) == 13
    assert lengths == [8, 4, 1]
    assert all(l & (l - 1) == 0 for l in lengths)


def test_abort_releases_resources_in_every_state():
    eng = _engine(max_slots=1, prefill_chunk=4)
    running, queued = _requests(2, prompt_len=6, steps=20)
    eng.submit(running)
    eng.submit(queued)
    for _ in range(4):
        eng.step()
    assert running.state is RequestState.RUNNING
    assert queued.state is RequestState.WAITING
    assert eng.abort(queued) and queued.finish_reason is FinishReason.ABORTED
    assert eng.abort(running) and running.slot is None
    assert eng.manager.n_free == 1
    assert not eng.has_work
    assert eng.abort(running) is False  # already finished
    # mid-prefill abort frees the lane and the reserved slot
    pre = _requests(1, prompt_len=6, steps=2, seed=9)[0]
    eng.submit(pre)
    eng.step()
    assert pre.state is RequestState.PREFILL
    assert eng.abort(pre)
    assert eng.manager.n_free == 1 and not eng.has_work
    assert len(eng.poll_finished()) == 3


def test_slot_reuse_and_bounded_concurrency():
    eng = _engine(max_slots=2)
    reqs = _requests(5)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_idle(max_steps=300)
    assert all(s.n_running <= 2 for s in stats)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert eng.manager.n_free == 2
    # slots were actually recycled: 5 requests cannot fit 2 slots at once
    assert {r.slot for r in reqs} == {None}
    assert len(eng.poll_finished()) == 5


def test_idle_fast_forward_to_next_arrival():
    eng = _engine()
    req = _requests(1, arrival_time=1.25)[0]
    eng.submit(req)
    eng.run_until_idle(max_steps=100)
    assert req.admit_time == pytest.approx(1.25)
    assert req.ttft > 0


# ------------------------------------------------------- finish semantics --
def test_stop_token_and_length_reasons():
    eng = _engine(max_slots=2)
    r_len = _requests(1, steps=3)[0]
    eng.submit(r_len)
    eng.run_until_idle(max_steps=100)
    assert r_len.finish_reason is FinishReason.LENGTH
    assert r_len.n_generated == 3

    # stop token: run once to learn the greedy continuation, then stop on it
    probe = _requests(1, seed=7, steps=4)[0]
    eng.submit(probe)
    eng.run_until_idle(max_steps=100)
    stop = int(probe.generated[1])
    replay = Request(prompt=probe.prompt.copy(), max_new_tokens=4,
                     stop_token=stop)
    eng.submit(replay)
    eng.run_until_idle(max_steps=100)
    assert replay.finish_reason is FinishReason.STOP
    assert replay.generated[-1] == stop
    assert replay.n_generated == 2


def test_finishes_at_max_seq_instead_of_overflowing():
    eng = _engine(max_slots=1, max_seq=12)
    req = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=50)
    eng.submit(req)
    eng.run_until_idle(max_steps=100)
    assert req.finish_reason is FinishReason.LENGTH
    assert req.prompt_len + req.n_generated <= 12


def test_rejects_prompt_beyond_max_seq():
    eng = _engine(max_seq=8)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=1))


# ------------------------------------------------- per-phase ratio tables --
def test_per_phase_ratios_converge_distinctly_on_hybrid_sim():
    """The acceptance property: under the virtual hybrid CPU, the ratio
    table holds distinct converged "prefill" (wide, compute-bound) and
    "decode" (compressed, bandwidth-bound) entries."""
    cost = HybridPhaseCost("ultra-125h")
    eng = ContinuousBatchingEngine(CFG, PARAMS, max_slots=4, max_seq=64,
                                   prefill_chunk=16, cost_model=cost)
    reqs = poisson_requests(10, rate=5.0, vocab_size=CFG.vocab_size,
                            prompt_len=32, max_new_tokens=8, seed=0)
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle(max_steps=2000)
    assert set(cost.table.keys()) >= {PREFILL, DECODE}
    pf, dec = cost.ratios(PREFILL), cost.ratios(DECODE)
    p_over_e_prefill = pf[:4].mean() / pf[4:12].mean()   # P cores / E cores
    p_over_e_decode = dec[:4].mean() / dec[4:12].mean()
    assert p_over_e_prefill > 1.8          # compute ratios stay wide
    assert p_over_e_decode < 1.5           # bandwidth ratios compress to ~1
    assert p_over_e_prefill > p_over_e_decode + 0.3


# ---------------------------------------------------------------- metrics --
def test_latency_report_and_traffic_determinism():
    a = poisson_requests(6, rate=100.0, vocab_size=CFG.vocab_size,
                         prompt_len=(4, 8), max_new_tokens=(2, 4), seed=5)
    b = poisson_requests(6, rate=100.0, vocab_size=CFG.vocab_size,
                         prompt_len=(4, 8), max_new_tokens=(2, 4), seed=5)
    for x, y in zip(a, b):
        assert x.arrival_time == y.arrival_time
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.max_new_tokens == y.max_new_tokens
    assert a[0].arrival_time == 0.0

    eng = _engine(max_slots=3, max_seq=16)
    for r in a:
        eng.submit(r)
    eng.run_until_idle(max_steps=500)
    rep = LatencyReport.from_requests(a, slo_ttft=1e9, slo_tpot=1e9)
    assert rep.n_finished == 6
    assert rep.ttft[50] <= rep.ttft[90] <= rep.ttft[99]
    assert rep.goodput > 0
    for r in a:
        assert r.ttft is not None and r.ttft >= 0
        assert r.tpot is not None and r.tpot >= 0
    strict = LatencyReport.from_requests(a, slo_ttft=-1.0)
    assert strict.goodput == 0.0


def test_latency_report_tolerates_aborted_requests():
    """A request aborted before its first token has no latency sample; it
    counts as finished but must not crash or NaN the percentiles."""
    eng = _engine(max_slots=1)
    served, aborted = _requests(2, steps=2)
    eng.submit(served)
    eng.submit(aborted)
    eng.step()                  # `served` occupies the only slot
    assert eng.abort(aborted)   # still WAITING: no first token ever
    eng.run_until_idle(max_steps=100)
    rep = LatencyReport.from_requests([served, aborted],
                                      slo_ttft=1e9, slo_tpot=1e9)
    assert rep.n_finished == 2
    assert np.isfinite(rep.ttft[50]) and np.isfinite(rep.tpot[50])
    assert rep.goodput > 0

    # aborting mid-decode must not flatter percentiles or goodput either
    eng2 = _engine(max_slots=2)
    fast = _requests(1, steps=2)[0]
    straggler = _requests(1, steps=20, seed=11)[0]
    eng2.submit(fast)
    eng2.submit(straggler)
    for _ in range(3):
        eng2.step()
    assert straggler.state is RequestState.RUNNING
    eng2.abort(straggler)
    eng2.run_until_idle(max_steps=100)
    rep2 = LatencyReport.from_requests([fast, straggler],
                                       slo_ttft=1e9, slo_tpot=1e9)
    assert rep2.goodput * rep2.duration == pytest.approx(1.0)  # only `fast`


def test_single_token_completion_has_no_tpot_sample():
    """max_new_tokens=1 finishes at prefill: a TTFT sample exists but no
    decode interval; it must not drag TPOT percentiles toward zero nor
    fail TPOT SLOs."""
    eng = _engine()
    one = _requests(1, steps=1)[0]
    two = _requests(1, steps=4, seed=13)[0]
    eng.submit(one)
    eng.submit(two)
    eng.run_until_idle(max_steps=100)
    assert one.finish_reason is FinishReason.LENGTH and one.n_generated == 1
    assert one.tpot is None and one.ttft is not None
    rep = LatencyReport.from_requests([one, two], slo_ttft=1e9, slo_tpot=1e-12)
    assert rep.tpot[50] == pytest.approx(two.tpot)  # only `two` sampled
    assert rep.goodput * rep.duration == pytest.approx(1.0)  # `one` passes SLO


def test_poisson_requests_accepts_numpy_scalar_lengths():
    reqs = poisson_requests(2, rate=0.0, vocab_size=32,
                            prompt_len=np.int64(5),
                            max_new_tokens=np.int32(3), seed=0)
    assert all(r.prompt_len == 5 and r.max_new_tokens == 3 for r in reqs)
