"""Assignment-grid invariants: 40 cells, skip rules, input specs."""

import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, cells, get_config, shape_supported


def test_grid_is_40_cells_with_8_skips():
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    supported = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(supported) == 32
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, _ in skipped)
    skipped_archs = {a for a, _, _ in skipped}
    assert "jamba-1.5-large-398b" not in skipped_archs
    assert "xlstm-1.3b" not in skipped_archs


def test_long500k_only_subquadratic():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert shape_supported(cfg, "long_500k") == cfg.sub_quadratic


def test_shape_table_matches_assignment():
    s = SHAPES
    assert (s["train_4k"].seq, s["train_4k"].batch) == (4096, 256)
    assert (s["prefill_32k"].seq, s["prefill_32k"].batch) == (32768, 32)
    assert (s["decode_32k"].seq, s["decode_32k"].batch) == (32768, 128)
    assert (s["long_500k"].seq, s["long_500k"].batch) == (524288, 1)
    assert s["decode_32k"].kind == "decode"
    assert s["long_500k"].kind == "decode"  # lowers serve_step, not train


def test_input_specs_shapes():
    # import inside: dryrun sets XLA_FLAGS at module import — only safe in
    # a test because jax is already initialized with 1 device here.
    from repro.launch.dryrun import input_specs

    cfg = get_config("granite-8b")
    tr = input_specs(cfg, SHAPES["train_4k"], n_micro=8)
    assert tr["batch"]["tokens"].shape == (8, 32, 4096)
    pf = input_specs(cfg, SHAPES["prefill_32k"])
    assert pf["tokens"].shape == (32, 32768)
    dc = input_specs(cfg, SHAPES["decode_32k"])
    assert dc["tokens"].shape == (128, 1)

    vlm = get_config("internvl2-26b")
    tv = input_specs(vlm, SHAPES["train_4k"])
    assert tv["batch"]["tokens"].shape == (8, 32, 4096 - 256)
    assert tv["batch"]["prefix_embeds"].shape == (8, 32, 256, 6144)

    audio = get_config("musicgen-medium")
    ta = input_specs(audio, SHAPES["train_4k"])
    assert ta["batch"]["embeds"].shape == (8, 32, 4096, 1536)
    assert ta["batch"]["embeds"].dtype == jnp.bfloat16
