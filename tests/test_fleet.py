"""Fleet-level recursive balancing end to end (ISSUE 6 tentpole).

Three heterogeneous nodes — two different flat machines plus a throttled
box whose nominal capacity is a 3x lie — serve seeded diurnal traffic
through the recursive :class:`~repro.fleet.FleetRouter`:

* the node-level ratio table converges to *real* (not nominal)
  throughput, so the throttled box gets the smallest share;
* a mid-run failure drains a node (WAITING requests rerouted, admitted
  work aborted) and the fleet re-converges, serving it again after
  recovery;
* learned routing beats round-robin on SLO goodput under identical
  traffic + failure;
* SLO-aware admission sheds/degrades with exact accounting;
* the traffic generator and the whole fleet run are seed-deterministic.

Also covers the :class:`~repro.serving.InflightDispatcher` liveness fix:
a replica failing mid-window must be masked out of EMA feedback instead
of dragging the ratio table with stale partial ``units=`` sums.
"""

import jax
import numpy as np
import pytest

from repro.fleet import (
    AdmissionController,
    Cluster,
    FleetRouter,
    NodeSpec,
    NodeEvent,
    diurnal_rate,
    failure_window,
    fleet_requests,
)
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    FinishReason,
    InflightDispatcher,
    LatencyReport,
    LinearPhaseCost,
    Request,
)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")

# >= 3 heterogeneous node types: two different flat machines + the same
# machine as "fast" but 3x-throttled (nominal capacity identical to fast).
THROTTLE = 3.0
SPECS = (
    NodeSpec("fast", "ultra-125h", max_slots=3),
    NodeSpec("mid", "core-12900k", max_slots=3),
    NodeSpec("slow", "ultra-125h", max_slots=3, throttle=THROTTLE),
)
SLO_TTFT, SLO_TPOT = 2.0, 0.25
N_REQUESTS = 32


@pytest.fixture(scope="module")
def model():
    return CFG, init_params(CFG, jax.random.key(0))


def build_cluster(model, specs=SPECS):
    cfg, params = model
    return Cluster.build(specs, cfg, params, max_seq=48, seed=0)


def traffic(n=N_REQUESTS, rate=8.0, seed=1):
    return fleet_requests(n, base_rate=rate, vocab_size=CFG.vocab_size,
                          prompt_len=(4, 20), max_new_tokens=(4, 8),
                          swing=0.6, period=4.0, seed=seed)


def fleet_run(model, policy, events=(), seed=1, admission=None):
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy=policy, slo_ttft=SLO_TTFT,
                         slo_tpot=SLO_TPOT, admission=admission)
    done = router.run(traffic(seed=seed), events)
    report = LatencyReport.from_requests(done, slo_ttft=SLO_TTFT,
                                         slo_tpot=SLO_TPOT)
    return router, done, report


@pytest.fixture(scope="module")
def learned_run(model):
    return fleet_run(model, "learned",
                     events=failure_window("mid", fail_at=1.5,
                                           recover_at=3.5))


@pytest.fixture(scope="module")
def rr_run(model):
    return fleet_run(model, "round_robin",
                     events=failure_window("mid", fail_at=1.5,
                                           recover_at=3.5))


# --------------------------------------------------------------- traffic --

def test_fleet_traffic_deterministic():
    a = traffic(seed=7)
    b = traffic(seed=7)
    assert [r.arrival_time for r in a] == [r.arrival_time for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [r.max_new_tokens for r in a] == [r.max_new_tokens for r in b]
    c = traffic(seed=8)
    assert [r.arrival_time for r in a] != [r.arrival_time for r in c]


def test_fleet_traffic_heavy_tail_and_bounds():
    reqs = fleet_requests(400, base_rate=10.0, vocab_size=64,
                          prompt_len=(4, 64), max_new_tokens=(2, 6), seed=3)
    lens = np.array([r.prompt_len for r in reqs])
    assert lens.min() >= 4 and lens.max() <= 64
    # heavy tail: median near the floor, some mass far above it
    assert np.median(lens) <= 16
    assert lens.max() >= 32
    assert all(2 <= r.max_new_tokens <= 6 for r in reqs)
    arr = np.array([r.arrival_time for r in reqs])
    assert (np.diff(arr) >= 0).all()


def test_diurnal_rate_schedule():
    rate = diurnal_rate(10.0, swing=0.5, period=8.0)
    assert rate(0.0) == pytest.approx(10.0)
    assert rate(2.0) == pytest.approx(15.0)   # crest at period/4
    assert rate(6.0) == pytest.approx(5.0)    # trough at 3*period/4
    with pytest.raises(ValueError):
        diurnal_rate(0.0)
    with pytest.raises(ValueError):
        diurnal_rate(1.0, swing=1.0)


def test_node_event_validation():
    with pytest.raises(ValueError):
        NodeEvent(time=0.0, node="x", kind="explode")
    with pytest.raises(ValueError):
        failure_window("x", fail_at=2.0, recover_at=1.0)


# --------------------------------------------------------- cluster model --

def test_cluster_validation(model):
    cfg, params = model
    with pytest.raises(ValueError):
        NodeSpec("x", "ultra-125h", throttle=0.5)
    with pytest.raises(ValueError):
        Cluster.build([NodeSpec("a", "ultra-125h"),
                       NodeSpec("a", "core-12900k")],
                      cfg, params, max_seq=32)


def test_throttle_blind_nominal_capacity(model):
    """The throttled box advertises full nominal bandwidth — the lie a
    static capacity partition falls for."""
    cluster = build_cluster(model)
    fast, slow = cluster.by_name["fast"], cluster.by_name["slow"]
    assert slow.nominal_capacity == pytest.approx(fast.nominal_capacity)


# ---------------------------------------------------- routing convergence --

def test_router_converges_to_real_throughput(learned_run):
    """The node table learns the 3x throttle that nominal capacity hides:
    the throttled box ends with a clearly smaller decode ratio and fewer
    routed requests than its identical-but-unthrottled twin."""
    router, _, _ = learned_run
    names = [n.name for n in router.cluster.nodes]
    i_fast, i_slow = names.index("fast"), names.index("slow")
    dec = router.table.ratios(DECODE)
    assert dec[i_slow] < 0.6 * dec[i_fast]
    assert router.routed[i_slow] < router.routed[i_fast]


def test_recursive_stats_tree(learned_run):
    """The fleet balancer's reports carry the per-node dispatcher stats as
    children — the recursive RatioTable-over-Balancers structure."""
    router, _, _ = learned_run
    st = router.last_stats[DECODE]
    assert len(st.children) >= 2
    for child in st.children:
        assert child.key == DECODE
        assert child.counts.shape == (1,)  # single-socket nodes
        assert np.isfinite(child.times).all()


def test_all_requests_finish(learned_run):
    router, done, report = learned_run
    assert len(done) == N_REQUESTS
    assert all(r.finish_time is not None for r in done)
    assert report.n_finished == N_REQUESTS


# ------------------------------------------------------- failure handling --

def test_failure_drains_and_reconverges(model):
    """Failing a node mid-run reroutes its queue, aborts admitted work,
    and — after recovery — the router serves it again."""
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned", slo_ttft=SLO_TTFT,
                         slo_tpot=SLO_TPOT)
    requests = traffic(n=32, rate=12.0, seed=2)  # hot: queues build up
    # recovery lands inside the arrival span (~1.5s at this rate) so the
    # recovered node can still win post-recovery submissions
    fail_at, recover_at = 0.5, 0.9
    events = failure_window("mid", fail_at=fail_at, recover_at=recover_at)
    timeline = sorted([(r.arrival_time, 0, r) for r in requests]
                      + [(e.time, 1, e) for e in events],
                      key=lambda x: (x[0], x[1]))
    i_mid = [n.name for n in cluster.nodes].index("mid")
    routed_at_recovery = None
    for t, kind, item in timeline:
        while router.has_work and router.now < t:
            router.step()
        if kind == 0:
            router.submit(item)
        else:
            router.apply_event(item)
            if item.kind == "fail":
                assert not cluster.by_name["mid"].active
            else:
                routed_at_recovery = router.routed[i_mid]
    while router.has_work:
        router.step()
    done = router.finished + [r for n in cluster.nodes
                              for r in n.poll_finished()]
    # the drained queue was rerouted and everything finished
    assert router.n_requeued > 0
    assert len(done) == 32 and all(r.finish_time is not None for r in done)
    aborted = [r for r in done if r.finish_reason is FinishReason.ABORTED]
    served = [r for r in done if r.finish_reason not in
              (FinishReason.ABORTED, FinishReason.SHED)]
    assert aborted, "failing a busy node must abort admitted work"
    assert len(served) >= 32 - len(aborted)
    # re-convergence: the recovered node takes traffic again
    assert routed_at_recovery is not None
    assert router.routed[i_mid] > routed_at_recovery


def test_failed_node_rejects_submit(model):
    cluster = build_cluster(model)
    cluster.by_name["mid"].fail()
    with pytest.raises(ValueError):
        cluster.by_name["mid"].submit(Request(prompt=np.arange(4),
                                              max_new_tokens=2))
    router = FleetRouter(cluster, policy="round_robin")
    for _ in range(4):  # RR must skip the failed node
        i = router.route(Request(prompt=np.arange(4), max_new_tokens=2))
        assert cluster.nodes[i].name != "mid"


# --------------------------------------------------------------- goodput --

def test_learned_beats_round_robin_goodput(learned_run, rr_run):
    """The tentpole claim at test scale: under identical diurnal traffic
    and the same failure window, measured routing strictly beats
    round-robin on SLO goodput (RR keeps feeding the throttled box)."""
    _, _, learned = learned_run
    _, _, rr = rr_run
    assert learned.goodput > rr.goodput


# ------------------------------------------------------------- admission --

def test_admission_shed_accounting(model):
    """Queue-cap shedding: rejected requests finish as SHED with zero
    engine work, and every ledger (controller, report) agrees."""
    adm = AdmissionController(queue_cap=4)
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned", admission=adm)
    burst = [Request(prompt=np.arange(6), max_new_tokens=4,
                     arrival_time=0.0) for _ in range(12)]
    done = router.run(burst)
    report = LatencyReport.from_requests(done, slo_ttft=SLO_TTFT,
                                         slo_tpot=SLO_TPOT)
    shed = [r for r in done if r.finish_reason is FinishReason.SHED]
    assert adm.n_shed == len(shed) == report.n_shed > 0
    assert all(r.n_generated == 0 for r in shed)
    assert report.n_finished == 12
    # served requests are untouched by the shed ones
    assert report.n_finished - report.n_shed == 12 - len(shed)


def test_admission_degrades_before_shedding(model):
    adm = AdmissionController(degrade_depth=0, degrade_factor=0.5)
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned", admission=adm)
    burst = [Request(prompt=np.arange(6), max_new_tokens=8,
                     arrival_time=0.0) for _ in range(6)]
    done = router.run(burst)
    report = LatencyReport.from_requests(done)
    assert adm.n_shed == 0
    assert adm.n_degraded == 6 == report.n_degraded
    assert all(r.degraded and r.max_new_tokens == 4 for r in done)
    assert all(r.n_generated <= 4 for r in done)


def test_admission_deadline_shedding(model):
    """A deadline the fleet's learned throughput says is unreachable sheds
    at the door; a generous one admits.  (Warm the estimator first — no
    estimate must mean no shedding.)"""
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned",
                         admission=AdmissionController())
    router.run(traffic(n=8, rate=50.0, seed=4))   # warm tps EWMAs
    adm = AdmissionController()
    router.admission = adm
    tight = Request(prompt=np.arange(16), max_new_tokens=8,
                    arrival_time=router.now, deadline=router.now + 1e-4)
    loose = Request(prompt=np.arange(16), max_new_tokens=8,
                    arrival_time=router.now, deadline=router.now + 60.0)
    assert router.submit(tight) is None
    assert tight.finish_reason is FinishReason.SHED
    assert router.submit(loose) is not None
    assert adm.n_shed == 1


def test_fleet_run_deterministic(model):
    """Same seed, same cluster, same events -> identical finish times and
    routing decisions."""
    events = failure_window("mid", fail_at=1.5, recover_at=3.5)
    r1, d1, _ = fleet_run(model, "learned", events=events, seed=9)
    r2, d2, _ = fleet_run(model, "learned", events=events, seed=9)
    assert r1.routed.tolist() == r2.routed.tolist()
    t1 = sorted(r.finish_time for r in d1)
    t2 = sorted(r.finish_time for r in d2)
    assert t1 == pytest.approx(t2)


# ------------------------------------- dispatcher liveness (satellite fix) --

def _lin_engine(model, speed=1.0, slots=2):
    cfg, params = model
    return ContinuousBatchingEngine(
        cfg, params, max_slots=slots, max_seq=32, prefill_chunk=8,
        cost_model=LinearPhaseCost(prefill_per_token=1e-3 * speed,
                                   decode_per_step=1e-3 * speed,
                                   decode_per_active=2e-3 * speed))


def test_dispatcher_masks_failed_replica_feedback(model):
    """A replica that dies mid-window must not ride its stale partial
    (units, seconds) sums into a later report: set_active clears its
    accumulator entries and the table's ratio carries over unmasked."""
    engines = [_lin_engine(model), _lin_engine(model, speed=3.0)]
    disp = InflightDispatcher(engines)
    # work lands only on replica 1: its window accumulates but never
    # flushes (a solo measurement carries no relative information)
    engines[1].submit(Request(prompt=np.arange(8), max_new_tokens=4))
    for _ in range(3):
        disp.step()
    assert disp._acc[DECODE][0][1] > 0
    assert not disp.last_stats  # nothing reported yet
    disp.set_active(1, False)
    for acc_u, acc_t in disp._acc.values():
        assert acc_u[1] == 0 and acc_t[1] == 0.0
    # routing now avoids the dead replica
    i, _ = disp.submit(Request(prompt=np.arange(8), max_new_tokens=4))
    assert i == 0
    disp.run_until_idle()
    # replica 0's solo window cannot pair with replica 1's stale sums, so
    # the shared table still carries the neutral prior for both
    np.testing.assert_allclose(disp.table.ratios(DECODE), [1.0, 1.0])


def test_dispatcher_reactivated_replica_relearns(model):
    """After recovery the replica is routed to and measured again — the
    table then learns the true 3x spread from fresh windows only."""
    engines = [_lin_engine(model), _lin_engine(model, speed=3.0)]
    disp = InflightDispatcher(engines)
    disp.set_active(1, False)
    disp.set_active(1, True)
    # concurrent bursts: backlog-aware routing spreads them over both
    # replicas, so the feedback windows pair up and flush
    for _ in range(3):
        for _ in range(6):
            disp.submit(Request(prompt=np.arange(8), max_new_tokens=4,
                                arrival_time=disp.now))
        disp.run_until_idle()
    dec = disp.table.ratios(DECODE)
    assert dec[0] > dec[1]  # replica 1 is 3x slower


def test_admission_estimate_accounts_for_inflight_remaining_tokens(model):
    """The deadline estimate reads actual in-flight decode backlog: parked
    requests with few remaining tokens (e.g. degraded admissions) raise
    the estimate less than long-lived ones, and a long request's
    contention is capped at the new request's own lifetime."""
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned",
                         admission=AdmissionController())
    router.run(traffic(n=8, rate=50.0, seed=4))   # warm the tps EWMAs
    adm = AdmissionController()
    probe = Request(prompt=np.arange(8), max_new_tokens=8,
                    arrival_time=router.now)

    def estimate_with_parked(max_new_tokens):
        if max_new_tokens == 0:
            return adm.estimate_finish(probe, router)
        parked = []
        for node in cluster.nodes:
            r = Request(prompt=np.arange(4), max_new_tokens=max_new_tokens,
                        arrival_time=router.now)
            node.submit(r)
            parked.append((node, r))
        est = adm.estimate_finish(probe, router)
        for node, r in parked:
            for e in node.engines:
                if r in e.outstanding():
                    e.abort(r)
        return est

    idle = estimate_with_parked(0)
    degraded = estimate_with_parked(2)    # short remainder (degraded-like)
    long_lived = estimate_with_parked(40)
    assert idle < degraded < long_lived
    # contention caps at the probe's lifetime: 40 remaining counts as 8
    node = cluster.nodes[0]
    r = Request(prompt=np.arange(4), max_new_tokens=40,
                arrival_time=router.now)
    node.submit(r)
    assert node.remaining_decode_tokens(cap=8) == 8
    assert node.remaining_decode_tokens() == 40


def test_fleet_serve_ratio_store_roundtrip(model, tmp_path, capsys):
    """--fleet --ratios round trip: the first run saves the node-level
    fleet table, the second warm-starts from it (ISSUE 7 satellite)."""
    from types import SimpleNamespace

    from repro.launch.serve import run_fleet_mode
    from repro.runtime import RatioStore, RatioTable

    cfg, params = model
    path = tmp_path / "fleet_ratios.json"
    args = SimpleNamespace(batch=2, seed=0, fleet_policy="learned",
                           fleet_admission=False, requests=6, rate=50.0,
                           prompt_len=8, steps=3, ratios=str(path))
    assert run_fleet_mode(args, cfg, params, max_seq=24) == 0
    first = capsys.readouterr().out
    assert "saved fleet node ratios" in first
    assert path.exists()
    saved = RatioTable(4)
    assert RatioStore(str(path)).load_into(saved)
    assert run_fleet_mode(args, cfg, params, max_seq=24) == 0
    second = capsys.readouterr().out
    assert "warm-started fleet node ratios" in second


def test_fleet_wide_outage_parks_and_recovers(model):
    """Every node down at once (ISSUE 9 satellite): arrivals during the
    fleet-wide window must park at the router — not crash ``route()`` —
    and the first recovery flushes them through full admission + routing.
    Goodput recovers: the parked-era requests are served, not aborted."""
    cluster = build_cluster(model)
    router = FleetRouter(cluster, policy="learned", slo_ttft=SLO_TTFT,
                         slo_tpot=SLO_TPOT)
    requests = traffic(n=24, rate=10.0, seed=3)
    fail_at, recover_at = 0.6, 1.4
    events = ([NodeEvent(time=fail_at, node=n.name, kind="fail")
               for n in cluster.nodes]
              + [NodeEvent(time=recover_at, node=n.name, kind="recover")
                 for n in cluster.nodes])
    done = router.run(requests, events)   # pre-fix: route() raised here
    assert router.n_parked > 0            # the window actually caught traffic
    assert len(done) == 24
    assert all(r.finish_time is not None for r in done)
    parked_era = [r for r in done
                  if fail_at <= r.arrival_time < recover_at]
    assert parked_era
    # parked requests never executed during the outage, so recovery must
    # serve every one of them to completion
    assert all(r.finish_reason in (FinishReason.LENGTH, FinishReason.STOP)
               for r in parked_era)
    served = [r for r in done if r.finish_reason not in
              (FinishReason.ABORTED, FinishReason.SHED)]
    report = LatencyReport.from_requests(served, slo_ttft=SLO_TTFT,
                                         slo_tpot=SLO_TPOT)
    assert report.goodput > 0
