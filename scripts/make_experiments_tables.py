"""Regenerate the EXPERIMENTS.md roofline tables from experiments/dryrun/.

  PYTHONPATH=src python scripts/make_experiments_tables.py > /tmp/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import ARCHS, SHAPES, get_config, shape_supported  # noqa


def load(mesh_tag):
    out = {}
    for p in glob.glob(f"experiments/dryrun/*__{mesh_tag}.json"):
        if mesh_tag == "16x16" and "2x16x16" in p:
            continue
        d = json.load(open(p))
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_row(d):
    mem = (d.get("peak_mem_bytes") or 0) / 1e9
    return (f"| {d['arch']} | {d['shape']} | {d['t_compute']:.4f} | "
            f"{d['t_memory']:.4f} | {d['t_collective']:.3f} | "
            f"{d.get('t_collective_tpu', 0):.3f} | {d['bottleneck']} | "
            f"{d['roofline_fraction']*100:.1f}% | "
            f"{d['useful_flops_fraction']*100:.0f}% | {mem:.1f} |")


def main():
    for tag, title in (("16x16", "Single pod (16x16 = 256 chips)"),
                       ("2x16x16", "Multi-pod (2x16x16 = 512 chips)")):
        cells = load(tag)
        if not cells:
            continue
        print(f"\n### {title}\n")
        print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
              "t_coll_tpu (s) | bound | roofline frac | useful flops | mem GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for arch in ARCHS:
            cfg = get_config(arch)
            for shape in SHAPES:
                if not shape_supported(cfg, shape):
                    print(f"| {arch} | {shape} | — | — | — | — | SKIP "
                          f"(needs sub-quadratic attn) | — | — | — |")
                    continue
                d = cells.get((arch, shape))
                print(fmt_row(d) if d else
                      f"| {arch} | {shape} | (missing) |||||||||")
        n_ok = len(cells)
        print(f"\n{n_ok} cells compiled on {title}.")


if __name__ == "__main__":
    main()
